"""Aggregate results/dryrun/*.json into the §Roofline table (markdown).

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_all(out_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        tag = os.path.basename(path)[:-5]
        arch, shape, mesh = tag.split("__")
        rf = r["roofline"]
        ma = r["memory"]
        rows.append({
            "arch": arch, "shape": shape, "mesh": mesh,
            "t_compute_ms": rf["t_compute_s"] * 1e3,
            "t_memory_ms": rf["t_memory_s"] * 1e3,
            "t_collective_ms": rf["t_collective_s"] * 1e3,
            "dominant": rf["dominant"],
            "useful": rf.get("useful_flops_ratio", 0.0),
            "mfu": rf.get("model_flops_util", 0.0),
            "peak_gib": ma["peak_bytes_per_chip"] / 2**30,
            "step_ms": rf["roofline_step_s"] * 1e3,
        })
    return rows


def _mitigation(r: dict) -> str:
    """One sentence: what would move the dominant term down (per spec)."""
    dom, arch, shape = r["dominant"], r["arch"], r["shape"]
    decode = "decode" in shape or "long" in shape
    if dom == "memory":
        if decode:
            return ("KV/state-cache traffic dominates: quantize the cache "
                    "to int8 (2x) and/or shard it over more chips")
        return ("activation residency: raise grad-accum / tighten the remat "
                "policy to cut temp traffic")
    if dom == "collective":
        if r["useful"] < 0.3:
            return ("sharding still wastes compute or reshards: next lever "
                    "is bf16 collectives + comm/compute overlap (XLA "
                    "latency-hiding over the layer scan)")
        if decode:
            return ("per-token TP all-reduces: batch more requests per step "
                    "or switch decode to data-parallel replicas")
        return ("TP activation all-reduces are floor-level: overlap them "
                "with the next layer's matmuls (latency-hiding scheduler) "
                "and compress cross-pod grads (optim/compress.py)")
    return ("compute-bound at high useful ratio: only kernel-level wins "
            "remain (fused attention kernel, MXU-aligned tiles)") \
        if r["useful"] > 0.5 else \
        ("compute-bound but wasteful: remove replicated compute "
         "(head padding / seq-parallel attention)")


def markdown_table(rows: list[dict], mesh: str | None = None,
                   mitigations: bool = True) -> str:
    sel = [r for r in rows if mesh is None or r["mesh"] == mesh]
    sel.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = ("| arch | shape | mesh | compute ms | memory ms | collective ms | "
           "dominant | useful | MFU | peak GiB/chip | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sel:
        mit = _mitigation(r) if mitigations else ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_ms']:.2f} | {r['t_memory_ms']:.2f} "
            f"| {r['t_collective_ms']:.2f} | **{r['dominant']}** "
            f"| {r['useful']:.2f} | {r['mfu']:.3f} | {r['peak_gib']:.2f} "
            f"| {mit} |")
    return hdr + "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = load_all()
    md = markdown_table(rows, args.mesh)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")
    print(md)
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"\ncells: {len(rows)}; dominant-term histogram: {doms}")


if __name__ == "__main__":
    main()
