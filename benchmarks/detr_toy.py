"""Shared toy-detector training for the accuracy benchmarks (Fig. 6a/6b).

COCO is unavailable offline; the paper's accuracy-vs-pruning experiments are
reproduced on the synthetic rectangle-detection task at reduced scale. The
trained checkpoint is cached under results/ so fig6 benchmarks and examples
share it."""
from __future__ import annotations

import dataclasses
import os
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.detector import (
    DetectorConfig, decoder_detection_loss, detection_loss, detector_apply,
    init_detector)
from repro.core.encoder import EncoderConfig
from repro.core.msdeform_attn import MSDeformAttnConfig
from repro.data.detection import eval_detection_ap, synth_detection_batch
from repro.msda import MSDADecoderConfig
from repro.optim.adamw import OptConfig, adamw_init, adamw_update

CKPT = "results/toy_detector.pkl"
CKPT_DEC = "results/toy_decoder_detector.pkl"


def toy_config(**attn_kw) -> DetectorConfig:
    attn = MSDeformAttnConfig(d_model=64, n_heads=4, n_levels=4, n_points=4,
                              **attn_kw)
    return DetectorConfig(
        encoder=EncoderConfig(attn=attn, n_blocks=2, d_ffn=128),
        img_size=64, n_classes=4, backbone_width=24)


def train_toy_detector(steps: int = 80, batch: int = 8, seed: int = 0,
                       log=print, force: bool = False):
    cfg = toy_config()
    if os.path.exists(CKPT) and not force:
        with open(CKPT, "rb") as f:
            return cfg, pickle.load(f)
    key = jax.random.PRNGKey(seed)
    params = init_detector(key, cfg)
    opt = adamw_init(params)
    opt_cfg = OptConfig(lr=2e-3, warmup_steps=10, total_steps=steps,
                        weight_decay=0.0)

    @jax.jit
    def step_fn(params, opt, img, tc, tb):
        (loss, extras), grads = jax.value_and_grad(
            detection_loss, has_aux=True)(params, cfg, img, tc, tb)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    for i in range(steps):
        img, tc, tb, _ = synth_detection_batch(
            jax.random.fold_in(key, i), batch, cfg.img_size, cfg.level_shapes)
        params, opt, loss = step_fn(params, opt, img, tc, tb)
        if i % 20 == 0:
            log(f"[toy-detr] step {i} loss {float(loss):.4f}")
    os.makedirs("results", exist_ok=True)
    host = jax.tree.map(np.asarray, params)
    with open(CKPT, "wb") as f:
        pickle.dump(host, f)
    return cfg, host


def toy_decoder_config(n_layers: int = 3, n_queries: int = 24,
                       **attn_kw) -> DetectorConfig:
    """Toy detector with the DETR-style decoder head (shared ValueCache)."""
    cfg = toy_config(**attn_kw)
    return dataclasses.replace(
        cfg, decoder=MSDADecoderConfig(n_layers=n_layers,
                                       n_queries=n_queries, d_ffn=128))


def train_toy_decoder_detector(steps: int = 400, batch: int = 8,
                               seed: int = 0, log=print, force: bool = False):
    """Train the decoder-head toy detector (set-prediction loss;
    Hungarian matching when scipy is installed, greedy fallback — see
    repro.core.detector.match_queries).

    The decoder's deformable cross-attention samples ONE shared value
    cache per forward (build-once, sample-everywhere). Checkpoint cached
    under results/ so the AP benchmark and EXPERIMENTS.md share it."""
    cfg = toy_decoder_config()
    if os.path.exists(CKPT_DEC) and not force:
        with open(CKPT_DEC, "rb") as f:
            return cfg, pickle.load(f)
    key = jax.random.PRNGKey(seed)
    params = init_detector(key, cfg)
    opt = adamw_init(params)
    opt_cfg = OptConfig(lr=2e-3, warmup_steps=10, total_steps=steps,
                        weight_decay=0.0)

    @jax.jit
    def step_fn(params, opt, img, gc, gb, ga):
        (loss, extras), grads = jax.value_and_grad(
            decoder_detection_loss, has_aux=True)(params, cfg, img,
                                                  gc, gb, ga)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    for i in range(steps):
        img, _, _, gt = synth_detection_batch(
            jax.random.fold_in(key, i), batch, cfg.img_size, cfg.level_shapes)
        params, opt, loss = step_fn(params, opt, img, gt["cls"], gt["box"],
                                    gt["active"])
        if i % 20 == 0:
            log(f"[toy-decoder] step {i} loss {float(loss):.4f}")
    os.makedirs("results", exist_ok=True)
    host = jax.tree.map(np.asarray, params)
    with open(CKPT_DEC, "wb") as f:
        pickle.dump(host, f)
    return cfg, host


def eval_ap(cfg: DetectorConfig, params, n_batches: int = 4, batch: int = 8,
            seed: int = 100) -> float:
    aps = []
    for i in range(n_batches):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        img, _, _, gt = synth_detection_batch(key, batch, cfg.img_size,
                                              cfg.level_shapes)
        cl, bx, _ = detector_apply(params, cfg, img)
        aps.append(eval_detection_ap(cl, bx, gt, n_classes=cfg.n_classes))
    return float(np.mean(aps))


def with_attn(cfg: DetectorConfig, **attn_kw) -> DetectorConfig:
    attn = dataclasses.replace(cfg.encoder.attn, **attn_kw)
    enc = dataclasses.replace(cfg.encoder, attn=attn)
    return dataclasses.replace(cfg, encoder=enc)
