"""Fig. 6a/6b reproduction: detection AP under each DEFA mechanism, and the
pruning / computation-cost reduction ratios.

Paper reference points (COCO, Deformable-DETR/DN-DETR/DINO): AP drops of
0.8 (FWP), 0.3 (PAP), 0.26 (range-narrowing), 0.07 (INT12); reductions of
43% fmap pixels / 84% sampling points / >50% compute. Ours are measured on
the synthetic toy task WITHOUT the paper's finetuning step, so the honest
comparison is directional (small AP deltas, large sparsity)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.detr_toy import eval_ap, toy_config, train_toy_detector, with_attn
from repro.core.detector import detector_apply
from repro.data.detection import synth_detection_batch


def run(log=print) -> dict:
    cfg, params = train_toy_detector(log=log)
    variants = {
        "baseline": {},
        "fwp": dict(fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6),
        "pap": dict(pap_mode="threshold", pap_threshold=0.02),
        "range_narrow": dict(range_narrow=(8.0, 6.0, 4.0, 3.0)),
        "int12": dict(act_bits=12, weight_bits=12),
        "int8": dict(act_bits=8, weight_bits=8),
        "defa_full": dict(fwp_mode="compact", fwp_k=1.0, fwp_capacity=0.6,
                          pap_mode="threshold", pap_threshold=0.02,
                          range_narrow=(8.0, 6.0, 4.0, 3.0),
                          act_bits=12, weight_bits=12),
    }
    out = {"ap": {}, "reduction": {}}
    for name, kw in variants.items():
        c = with_attn(cfg, **kw)
        out["ap"][name] = eval_ap(c, params)
        log(f"[fig6a] AP[{name}] = {out['ap'][name]:.4f}")

    # --- Fig 6b: reduction ratios from the DEFA stats ----------------------
    c = with_attn(cfg, fwp_mode="mask", fwp_k=1.0,
                  pap_mode="threshold", pap_threshold=0.02)
    key = jax.random.PRNGKey(7)
    img, _, _, _ = synth_detection_batch(key, 8, cfg.img_size, cfg.level_shapes)
    _, _, aux = detector_apply(params, c, img, collect_stats=True)
    # block 0 has no FWP mask yet; use block 1+ stats
    pap_keep = float(np.mean([float(b["point_alive_frac"])
                              for b in aux["blocks"]]))
    fwp_keep = float(np.mean([float(b["fwp_keep_frac"])
                              for b in aux["blocks"][:-1]]))
    # compute-cost reduction on MSGS+agg+V-projection (the paper's >50%):
    # V proj scales with kept pixels; sampling/aggregation with kept points.
    lp = 16
    compute_frac = 0.5 * fwp_keep + 0.5 * pap_keep
    out["reduction"] = {
        "fmap_pixels_pruned_pct": 100 * (1 - fwp_keep),
        "sampling_points_pruned_pct": 100 * (1 - pap_keep),
        "msgs_compute_saved_pct": 100 * (1 - compute_frac),
        "paper_fmap_pct": 43.0, "paper_points_pct": 84.0,
        "paper_compute_pct": 50.0,
    }
    for k, v in out["reduction"].items():
        log(f"[fig6b] {k} = {v:.1f}")
    return out


if __name__ == "__main__":
    run()
